# One function per paper table. Print ``name,us_per_call,derived`` CSV.
"""Benchmark harness entry point.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig4,...]

Suites:
    table1   — paper Table I analog (python/numpy/XLA GEE runtimes)
    fig3     — strong scaling (subprocess device sweep)
    fig4     — Erdős–Rényi edge-count linearity
    kernels  — kernel-path microbenches
    encoder  — unified Embedder API: per-backend edges/s side by side
               + plan-cache (host packing removed on refit)
    serving  — online-service update latency vs full re-embed + queries
               + sharded-engine rows incl. per-shard accumulator memory
    index    — IVF index QPS + recall@10 vs the exact full scan
    roofline — per-cell roofline terms from dry-run artifacts

Schema check: after each suite runs, the rows it emitted are checked
against the driver's ``expected_keys()`` declaration — a driver that
silently emits nothing (or loses a row to a refactor) FAILS the run
instead of passing vacuously (the `make bench-smoke` CI gate relies on
this).
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

SUITES = {
    "table1": "benchmarks.table1_runtimes",
    "fig4": "benchmarks.fig4_edges",
    "kernels": "benchmarks.kernels_bench",
    "encoder": "benchmarks.encoder_bench",
    "serving": "benchmarks.serving_bench",
    "index": "benchmarks.index_bench",
    "fig3": "benchmarks.fig3_scaling",
    "roofline": "benchmarks.roofline_report",
}


def _check_schema(suite: str, module) -> None:
    """Every key the driver declares must have been emitted, must map
    to a scheme-conformant registry name (``repro_bench_*_us``), and —
    when the obs layer is live — must actually be present in the
    registry (emit() mirrors every row there)."""
    from benchmarks import common
    from repro import obs
    expected_keys = getattr(module, "expected_keys", None)
    if expected_keys is None:
        return
    expected = list(expected_keys())
    emitted = set(common.EMITTED)
    missing = [k for k in expected if k not in emitted]
    if missing:
        raise RuntimeError(
            f"suite {suite!r} finished without emitting expected "
            f"result keys {missing} — a silently-empty benchmark is a "
            "failure, not a pass")
    bad = [k for k in expected
           if not obs.valid_metric_name(common.metric_name(k))]
    if bad:
        raise RuntimeError(
            f"suite {suite!r} declares row names {bad} that do not map "
            "onto the repro_<subsystem>_<metric> registry scheme")
    if obs.enabled():
        gauges = obs.snapshot(prefix="repro_bench")["gauges"]
        names = {g.split("{")[0] for g in gauges}
        lost = [k for k in expected
                if common.metric_name(k) not in names]
        if lost:
            raise RuntimeError(
                f"suite {suite!r} rows {lost} never reached the "
                "metrics registry — emit() and the registry disagree")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of " + ",".join(SUITES))
    ap.add_argument("--quick", action="store_true",
                    help="tiny graphs, minimal iters: exercises every "
                         "chosen driver end-to-end in seconds (the "
                         "`make bench-smoke` CI gate), numbers are NOT "
                         "meaningful measurements")
    ap.add_argument("--shards", type=int, default=None,
                    help="shard count for the serving suite's "
                         "partitioned-engine rows (default 2)")
    args = ap.parse_args()
    from benchmarks import common
    if args.quick:
        common.QUICK = True
    if args.shards is not None:
        common.SHARDS = max(1, args.shards)
    chosen = args.only.split(",") if args.only else list(SUITES)

    print("name,us_per_call,derived")
    failures = []
    for suite in chosen:
        try:
            if suite not in SUITES:
                raise ValueError(f"unknown suite {suite}")
            module = importlib.import_module(SUITES[suite])
            common.EMITTED.clear()
            module.run()
            _check_schema(suite, module)
        except Exception:
            traceback.print_exc()
            failures.append(suite)
    if failures:
        print(f"# FAILED suites: {failures}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
