"""IVF index benchmark: QPS and recall@10 vs the exact full scan.

The index's pitch (ROADMAP item 4) is sub-linear top-k: assign rows to
their nearest class centroid, probe only the ``nprobe`` most promising
cells per query.  This driver measures, at n in {1e5, 1e6} on an SBM
graph whose communities match the label classes (the regime GEE's
centroid quantizer is built for):

    index_build_{tag}          full quantization of all owned rows
    index_topk256_exact_{tag}  256-query exact scan (the baseline)
    index_topk256_ivf_{tag}    same batch through the index at the
                               default nprobe
    index_recall10_{tag}       fraction of the exact top-10 the index
                               returns (value column = fraction, not a
                               latency — the derived column repeats it)

The acceptance bar: at n=1e6 the ivf row must beat the exact row on
queries/s while recall@10 stays >= 0.9 (a WARN line flags any miss —
`make bench-smoke` runs the quick variant so a broken index fails CI
via the `expected_keys` schema check).
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.graph.edges import make_labels
from repro.graph.generators import sbm
from repro.serving.engine import ServingEngine
from repro.serving.store import GraphStore

K = 10
DEG = 10                 # expected edges per node
QBATCH = 256
LABEL_FRAC = 0.5


def _sizes() -> list:
    return [2_000] if common.QUICK else [100_000, 1_000_000]


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    keys = []
    for n in _sizes():
        tag = f"n{n}"
        keys += [f"index_build_{tag}",
                 f"index_topk{QBATCH}_exact_{tag}",
                 f"index_topk{QBATCH}_ivf_{tag}",
                 f"index_recall10_{tag}"]
    return keys


def run() -> None:
    rng = np.random.default_rng(0)
    for n in _sizes():
        tag = f"n{n}"
        g, truth = sbm(n, K, DEG * n, p_in=0.9, seed=0)
        Y = make_labels(n, K, LABEL_FRAC, rng, true_labels=truth)
        eng = ServingEngine(GraphStore(g, Y, K))

        t0 = time.perf_counter()
        eng.enable_index()
        emit(f"index_build_{tag}", time.perf_counter() - t0,
             f"K={K} cells")

        nodes = rng.integers(0, n, QBATCH).astype(np.int32)
        t_exact = time_it(
            lambda eng=eng, nodes=nodes:
            eng.query_topk(nodes, k=10, mode="exact"))
        emit(f"index_topk{QBATCH}_exact_{tag}", t_exact,
             f"{QBATCH / t_exact:,.0f} q/s")
        t_ivf = time_it(
            lambda eng=eng, nodes=nodes:
            eng.query_topk(nodes, k=10, mode="ivf"))
        nprobe = eng.stats()["index"]["nprobe"]
        speedup = t_exact / t_ivf
        emit(f"index_topk{QBATCH}_ivf_{tag}", t_ivf,
             f"{QBATCH / t_ivf:,.0f} q/s nprobe={nprobe} "
             f"speedup={speedup:.1f}x")

        ei, _ = eng.query_topk(nodes, k=10, mode="exact")
        ii, _ = eng.query_topk(nodes, k=10, mode="ivf")
        recall = float(np.mean([
            len(set(a.tolist()) & set(b.tolist())) / 10
            for a, b in zip(ei, ii)]))
        emit(f"index_recall10_{tag}", recall,
             f"recall@10={recall:.3f} (fraction) nprobe={nprobe}")
        if recall < 0.9:
            print(f"# WARN index recall@10 {recall:.3f} < 0.9 "
                  f"target at {tag}")
        if not common.QUICK and speedup <= 1.0:
            print(f"# WARN index ivf not faster than exact at {tag} "
                  f"({speedup:.2f}x)")


if __name__ == "__main__":
    run()
