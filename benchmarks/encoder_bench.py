"""Unified-API benchmark: every registered backend side by side on the
same graph, plus the plan-cache effect.

Two claims measured (ISSUE 2 acceptance):
  * per-backend edges/s through the ONE `Embedder.fit` entry point —
    the conformance suite proves they agree on Z, this shows what each
    strategy costs on this host;
  * `plan()` caching removes repeat host-side packing: with jit ALREADY
    WARM, a fit on fresh arrays (forced plan rebuild) vs a refit on the
    cached plan — the gap is purely the host packing/padding/capacity-
    measurement cost, largest for the pallas destination-sort and the
    distributed capacity histogram.  (Compile time is excluded on both
    sides so the metric isolates what the cache actually removes.)
"""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import emit, time_it
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph, make_labels
from repro.graph.generators import erdos_renyi

# (backend, n, s, cfg overrides) — pallas interpret mode and the p=1
# distributed modes are correctness paths on this container, so they
# run scaled-down; xla/numpy/streaming run at the real CPU hot-path size
SIZES = {
    "xla": (100_000, 1_000_000, {}),
    "numpy": (100_000, 1_000_000, {}),
    "streaming": (100_000, 1_000_000, {"chunk_size": 1 << 18}),
    "pallas": (2_000, 16_000, {"tile_n": 256, "edge_block": 256}),
    "distributed:replicated": (20_000, 200_000, {}),
    "distributed:reduce_scatter": (20_000, 200_000, {}),
    "distributed:a2a": (20_000, 200_000, {}),
    "distributed:ring": (20_000, 200_000, {}),
}
K = 16


def run() -> None:
    rng = np.random.default_rng(0)
    for backend, (n, s, over) in SIZES.items():
        g = erdos_renyi(n, s, seed=1, weighted=True)
        Y = make_labels(n, K, 0.1, rng)
        emb = Embedder(EncoderConfig(K=K, **over), backend=backend)
        emb.fit(g, Y)                       # warm the jit compiles

        t_warm = time_it(lambda: emb.refit(Y).Z_, warmup=1, iters=3)

        # direct host-side plan cost — exactly what a cache hit skips:
        # fresh array objects force a rebuild (identity cache miss),
        # emb.plan() alone runs no device embed and no compile
        plans = []
        for _ in range(3):
            g2 = Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n)
            t0 = time.perf_counter()
            emb.plan(g2)
            plans.append(time.perf_counter() - t0)
        t_plan = sorted(plans)[1]

        tag = backend.replace(":", "_")
        emit(f"encoder/{tag}/fit_warm", t_warm,
             f"s={s};edges_per_s={s / t_warm:,.0f}")
        emit(f"encoder/{tag}/plan_cache", t_plan,
             f"plan_build_s={t_plan:.4f};cached_refit_s={t_warm:.4f};"
             f"overhead_removed_per_fit="
             f"{100 * t_plan / (t_plan + t_warm):.1f}%;"
             f"plan_stats=built{emb.plan_stats['built']}"
             f"/hits{emb.plan_stats['hits']}")


if __name__ == "__main__":
    run()
