"""Unified-API benchmark: every registered backend side by side on the
same graph, plus BOTH plan-cache tiers.

Claims measured:
  * per-backend edges/s through the ONE `Embedder.fit` entry point —
    the conformance suite proves they agree on Z, this shows what each
    strategy costs on this host;
  * tier 1 (identity): with jit ALREADY WARM, a fit on fresh arrays
    (forced plan rebuild) vs a refit on the cached plan — the gap is
    purely the host packing/padding/capacity-measurement cost, largest
    for the pallas destination-sort and the distributed capacity
    histogram.  (Compile time is excluded on both sides; the persistent
    tier is DISABLED here so the rebuild is a true host rebuild.)
  * tier 2 (persistent, ISSUE 3): plan time in a genuinely COLD
    PROCESS (fresh interpreter, empty disk cache) vs a warm-persistent
    process (fresh interpreter, plan host half on disk) — what a
    restart / CI rerun / new serving replica actually pays.
"""
from __future__ import annotations

import json
import os
import shutil
import subprocess
import sys
import tempfile
import time

import numpy as np

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import Graph, make_labels
from repro.graph.sources import SyntheticSource

# (backend, n, s, cfg overrides) — pallas interpret mode and the p=1
# distributed modes are correctness paths on this container, so they
# run scaled-down; xla/numpy/streaming run at the real CPU hot-path size
SIZES = {
    "xla": (100_000, 1_000_000, {}),
    "numpy": (100_000, 1_000_000, {}),
    "streaming": (100_000, 1_000_000, {"chunk_size": 1 << 18}),
    "pallas": (2_000, 16_000, {"tile_n": 256, "edge_block": 256}),
    "distributed:replicated": (20_000, 200_000, {}),
    "distributed:reduce_scatter": (20_000, 200_000, {}),
    "distributed:a2a": (20_000, 200_000, {}),
    "distributed:ring": (20_000, 200_000, {}),
}
QUICK_SIZES = {
    "xla": (500, 4_000, {}),
    "numpy": (500, 4_000, {}),
    "streaming": (500, 4_000, {"chunk_size": 1 << 10}),
    "pallas": (500, 4_000, {"tile_n": 64, "edge_block": 128}),
    "distributed:ring": (500, 4_000, {}),
}
K = 16

# the tier-2 (persistent, cross-process) measurement poles: pallas (the
# O(s log s) destination sort) and xla (w_eff only)
PERSIST = [("pallas", 100_000, 1_000_000,
            {"tile_n": 256, "edge_block": 256}),
           ("xla", 100_000, 1_000_000, {"laplacian": True})]
QUICK_PERSIST = [("pallas", 500, 4_000,
                  {"tile_n": 64, "edge_block": 128})]


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    keys = []
    for backend in common.pick(SIZES, QUICK_SIZES):
        tag = backend.replace(":", "_")
        keys += [f"encoder/{tag}/fit_warm", f"encoder/{tag}/plan_cache"]
    for backend, *_ in common.pick(PERSIST, QUICK_PERSIST):
        tag = backend.replace(":", "_")
        keys += [f"encoder/{tag}/plan_cold_process",
                 f"encoder/{tag}/plan_warm_persistent"]
    return keys

# Child for the tier-2 measurement: plan (no embed, no compile) a known
# synthetic graph against the given cache dir, report plan seconds and
# counters.  Spawned twice: cold (empty dir) then warm (entry on disk).
_CHILD = r"""
import json, sys, time
from repro.encoder import Embedder, EncoderConfig
from repro.graph.sources import SyntheticSource

backend, n, s, cache = (sys.argv[1], int(sys.argv[2]), int(sys.argv[3]),
                        sys.argv[4])
over = json.loads(sys.argv[5])
src = SyntheticSource("erdos_renyi", n=n, s=s, seed=1, weighted=True)
g = src.graph()          # materialize outside the timed region
emb = Embedder(EncoderConfig(K=16, **over), backend=backend,
               plan_cache=cache)
t0 = time.perf_counter()
emb.plan(g)
dt = time.perf_counter() - t0
print(json.dumps({"plan_s": dt, **emb.plan_stats}))
"""


def _plan_in_fresh_process(backend: str, n: int, s: int, over: dict,
                           cache: str) -> dict:
    out = subprocess.run(
        [sys.executable, "-c", _CHILD, backend, str(n), str(s), cache,
         json.dumps(over)],
        env=dict(os.environ), capture_output=True, text=True, timeout=600)
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    return json.loads(out.stdout.strip().splitlines()[-1])


def run() -> None:
    rng = np.random.default_rng(0)
    sizes = common.pick(SIZES, QUICK_SIZES)
    iters = common.pick(3, 1)
    for backend, (n, s, over) in sizes.items():
        src = SyntheticSource("erdos_renyi", n=n, s=s, seed=1,
                              weighted=True)
        g = src.graph()
        Y = make_labels(n, K, 0.1, rng)
        # persistent tier off: the t_plan loop below must measure a TRUE
        # host rebuild, not a disk load
        emb = Embedder(EncoderConfig(K=K, **over), backend=backend,
                       plan_cache=None)
        emb.fit(src, Y)                     # warm the jit compiles

        t_warm = time_it(lambda emb=emb, Y=Y: emb.refit(Y).Z_,
                         warmup=1, iters=iters)

        # direct host-side plan cost — exactly what a cache hit skips:
        # fresh array objects force a rebuild (identity cache miss),
        # emb.plan() alone runs no device embed and no compile
        plans = []
        for _ in range(iters):
            g2 = Graph(g.u.copy(), g.v.copy(), g.w.copy(), g.n)
            t0 = time.perf_counter()
            emb.plan(g2)
            plans.append(time.perf_counter() - t0)
        t_plan = sorted(plans)[len(plans) // 2]

        tag = backend.replace(":", "_")
        emit(f"encoder/{tag}/fit_warm", t_warm,
             f"s={s};edges_per_s={s / t_warm:,.0f}")
        emit(f"encoder/{tag}/plan_cache", t_plan,
             f"plan_build_s={t_plan:.4f};cached_refit_s={t_warm:.4f};"
             f"overhead_removed_per_fit="
             f"{100 * t_plan / (t_plan + t_warm):.1f}%;"
             f"plan_stats=built{emb.plan_stats['built']}"
             f"/hits{emb.plan_stats['hits']}")

    # -- tier 2: cold process vs warm-persistent-cache (ISSUE 3) ----------
    # each child is a genuinely fresh interpreter
    for backend, n, s, over in common.pick(PERSIST, QUICK_PERSIST):
        cache = tempfile.mkdtemp(prefix="repro-plan-bench-")
        try:
            cold = _plan_in_fresh_process(backend, n, s, over, cache)
            assert cold["built"] == 1 and cold["disk_stores"] == 1, cold
            warm = _plan_in_fresh_process(backend, n, s, over, cache)
            assert warm["disk_hits"] == 1 and warm["built"] == 0, warm
            tag = backend.replace(":", "_")
            emit(f"encoder/{tag}/plan_cold_process", cold["plan_s"],
                 f"s={s};fresh interpreter, empty cache")
            emit(f"encoder/{tag}/plan_warm_persistent", warm["plan_s"],
                 f"s={s};speedup={cold['plan_s'] / warm['plan_s']:.1f}x;"
                 f"host half loaded from disk, only device placement "
                 f"re-ran")
        finally:
            shutil.rmtree(cache, ignore_errors=True)


if __name__ == "__main__":
    run()
