"""Paper Figure 3 analog: strong scaling of parallel GEE.

The paper scales 1->24 cores on Friendster (11x at 24).  This container
has ONE physical core, so wall-clock cannot show parallel speedup;
instead we measure what static SPMD sharding controls: PER-SHARD WORK
(edges processed per device) and its balance across shards, on 1..8
host devices in subprocesses.  Per-shard work halving as devices double
is exactly the property that yields linear strong scaling on parallel
hardware (and is what Ligra's work-stealing delivered dynamically).

We also report wall time for transparency — expect ~flat-to-worse on a
single physical core (oversubscription), which is itself evidence the
sharding added no algorithmic overhead.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys

from benchmarks.common import emit

_WORKER = r"""
import json, time
import numpy as np, jax
from repro.graph.generators import erdos_renyi
from repro.graph.edges import make_labels
from repro.encoder import Embedder, EncoderConfig

g = erdos_renyi(100_000, 2_000_000, seed=1)
Y = make_labels(g.n, 50, 0.10, np.random.default_rng(0))
P = len(jax.devices())
emb = Embedder(EncoderConfig(K=50), backend="distributed:ring")
emb.fit(g, Y)                           # plan + warm compile
t0 = time.perf_counter()
for _ in range(3):
    jax.block_until_ready(emb.refit(Y).Z_)
dt = (time.perf_counter() - t0) / 3
print("RESULT " + json.dumps({
    "devices": P, "wall_s": dt, "edges_per_shard": g.s / P,
    "dropped": emb.last_info_["dropped"]}))
"""


def run() -> None:
    here = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    base = None
    for ndev in (1, 2, 4, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = \
            f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = os.path.join(here, "src")
        r = subprocess.run([sys.executable, "-c", _WORKER], env=env,
                           capture_output=True, text=True, timeout=600)
        if r.returncode != 0:
            emit(f"fig3/devices{ndev}/FAILED", 0.0, r.stderr[-200:])
            continue
        line = [ln for ln in r.stdout.splitlines()
                if ln.startswith("RESULT ")][0]
        d = json.loads(line[len("RESULT "):])
        if base is None:
            base = d["edges_per_shard"]
        emit(f"fig3/devices{ndev}/wall", d["wall_s"],
             f"edges_per_shard={d['edges_per_shard']:.0f};"
             f"work_reduction={base / d['edges_per_shard']:.2f}x;"
             f"dropped={d['dropped']}")


if __name__ == "__main__":
    run()
