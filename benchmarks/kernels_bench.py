"""Kernel-path microbenchmarks (CPU interpret mode timings are NOT TPU
performance — emitted for regression tracking of the wrappers, plus the
jnp GEE hot path which IS the CPU production path).  GEE paths go
through the unified Embedder so what we time is what callers run."""
from __future__ import annotations

import jax

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.kernels import ops

import numpy as np


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    sizes = common.pick((1_000_000, 4_000_000), (4_000, 8_000))
    return ([f"kernels/gee_xla_scatter/s{s}" for s in sizes]
            + ["kernels/gee_pallas_interpret/s16000",
               "kernels/flash_attn_interpret/s256"])


def run() -> None:
    rng = np.random.default_rng(0)
    n, k = common.pick((100_000, 50), (1_000, 8))
    # jnp scatter hot path at a few scales
    for s in common.pick((1_000_000, 4_000_000), (4_000, 8_000)):
        g = erdos_renyi(n, s, seed=s)
        Y = make_labels(g.n, k, 0.1, rng)
        emb = Embedder(EncoderConfig(K=k), backend="xla").fit(g, Y)
        t = time_it(lambda emb=emb, Y=Y: emb.refit(Y).Z_,
                    warmup=1, iters=3)
        emit(f"kernels/gee_xla_scatter/s{s}", t,
             f"edges_per_s={s / t:,.0f}")

    # pallas gee kernel in interpret mode (correctness path); the plan
    # (destination packing) is cached, so refits time the kernel alone
    g = erdos_renyi(2_000, 16_000, seed=7)
    Y = make_labels(g.n, 16, 0.2, rng)
    emb = Embedder(EncoderConfig(K=16, tile_n=256, edge_block=256),
                   backend="pallas").fit(g, Y)
    t = time_it(lambda: emb.refit(Y).Z_, warmup=1, iters=2)
    emit("kernels/gee_pallas_interpret/s16000", t, "correctness path")

    # flash attention kernel interpret vs jnp reference
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    t = time_it(lambda: ops.flash_attention(q, k, v, bq=128, bk=128),
                warmup=1, iters=2)
    emit("kernels/flash_attn_interpret/s256", t, "correctness path")


if __name__ == "__main__":
    run()
