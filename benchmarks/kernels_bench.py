"""Kernel-path microbenchmarks (CPU interpret mode timings are NOT TPU
performance — emitted for regression tracking of the wrappers, plus the
jnp GEE hot path which IS the CPU production path).  GEE paths go
through the unified Embedder so what we time is what callers run.

Pallas rows report the RESOLVED compile/interpret mode
(`kernels.resolve_interpret`) in their derived column, and the suite
prints a loud warning when a "pallas" row was measured in interpret
mode — an interpreted kernel timing mistaken for kernel performance is
exactly the bug the auto-resolved mode exists to surface.  The
``*_roofline`` rows report achieved-vs-roofline HBM bandwidth from the
`repro.launch.autotune` traffic models (meaningful on TPU; in
interpret mode they quantify how far the interpreter is from the
memory-bound target)."""
from __future__ import annotations

import jax

from benchmarks import common
from benchmarks.common import emit, time_it
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import erdos_renyi
from repro.kernels import ops
from repro.kernels.gee_scatter import interpret_mode_name, resolve_interpret
from repro.launch.autotune import (scatter_traffic_bytes,
                                   topk_traffic_bytes)
from repro.launch.roofline import HBM_BW

import numpy as np


def _topk_m() -> int:
    return common.pick(50_000, 2_000)


def expected_keys() -> list:
    """Schema for `benchmarks.run`'s silently-empty-driver check."""
    sizes = common.pick((1_000_000, 4_000_000), (4_000, 8_000))
    return ([f"kernels/gee_xla_scatter/s{s}" for s in sizes]
            + ["kernels/gee_pallas/s16000",
               "kernels/gee_pallas_owned/s16000",
               "kernels/gee_scatter_roofline/s16000",
               f"kernels/topk_fused/m{_topk_m()}",
               f"kernels/topk_fused_roofline/m{_topk_m()}",
               "kernels/flash_attn_interpret/s256"])


def _bw_note(moved: int, seconds: float, mode: str) -> str:
    gbps = moved / seconds / 1e9 if seconds > 0 else 0.0
    frac = gbps * 1e9 / HBM_BW
    return (f"achieved={gbps:.3f}GB/s frac={frac * 100:.3f}% "
            f"of {HBM_BW / 1e9:.0f}GB/s mode={mode}")


def run() -> None:
    rng = np.random.default_rng(0)
    n, k = common.pick((100_000, 50), (1_000, 8))
    # jnp scatter hot path at a few scales
    for s in common.pick((1_000_000, 4_000_000), (4_000, 8_000)):
        g = erdos_renyi(n, s, seed=s)
        Y = make_labels(g.n, k, 0.1, rng)
        emb = Embedder(EncoderConfig(K=k), backend="xla").fit(g, Y)
        t = time_it(lambda emb=emb, Y=Y: emb.refit(Y).Z_,
                    warmup=1, iters=3)
        emit(f"kernels/gee_xla_scatter/s{s}", t,
             f"edges_per_s={s / t:,.0f}")

    # pallas gee kernel, mode resolved per platform; the plan
    # (destination packing) is cached, so refits time the kernel alone
    interp = resolve_interpret("auto")
    mode = interpret_mode_name(interp)
    g = erdos_renyi(2_000, 16_000, seed=7)
    Y = make_labels(g.n, 16, 0.2, rng)
    emb = Embedder(EncoderConfig(K=16, tile_n=256, edge_block=256),
                   backend="pallas").fit(g, Y)
    t = time_it(lambda: emb.refit(Y).Z_, warmup=1, iters=2)
    emit("kernels/gee_pallas/s16000", t, f"mode={mode}")
    d = emb._plan.data
    moved = scatter_traffic_bytes(d["T"], d["rows"].shape[1],
                                  d["rows"].shape[2], 256, d["kdim"])
    emit("kernels/gee_scatter_roofline/s16000", t,
         _bw_note(moved, t, mode))

    # owned-rows pallas: same graph, a proper sub-range partition —
    # the kernel plus the O(n/p) accumulator path sharded rebuilds use
    emb_o = Embedder(EncoderConfig(K=16, tile_n=256, edge_block=256,
                                   row_partition=(0, 1_000)),
                     backend="pallas").fit(g, Y)
    t = time_it(lambda: emb_o.refit(Y).Z_, warmup=1, iters=2)
    emit("kernels/gee_pallas_owned/s16000", t,
         f"n_local=1000 mode={mode}")

    # fused normalize+cosine+top-k query kernel over a candidate slice
    from repro.serving import queries as Q
    m, K, nq, topk = _topk_m(), 16, 32, 10
    Z = np.asarray(rng.normal(size=(m, K)), np.float32)
    import jax.numpy as jnp
    Zn = Q.normalize_rows(jnp.asarray(Z))
    qnodes = rng.integers(0, m, nq).astype(np.int32)
    q = Zn[jnp.asarray(qnodes)]
    block_rows = 1 << 14
    t = time_it(lambda: Q.topk_cosine_fused(Zn, q, qnodes, k=topk,
                                            block_rows=block_rows),
                warmup=1, iters=2)
    emit(f"kernels/topk_fused/m{m}", t, f"nq={nq} k={topk} mode={mode}")
    bucket = Q._bucket_rows(m, block_rows)
    moved = topk_traffic_bytes(m, K, nq, topk, bucket)
    emit(f"kernels/topk_fused_roofline/m{m}", t, _bw_note(moved, t, mode))

    if interp:
        print("WARNING: pallas rows above were measured in INTERPRET "
              "mode (no pallas lowering on "
              f"{jax.default_backend()!r}) — these are wrapper "
              "correctness timings, NOT kernel performance; rerun on "
              "TPU/GPU for compiled numbers.")

    # flash attention kernel interpret vs jnp reference
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (1, 4, 256, 64))
    k = jax.random.normal(ks[1], (1, 2, 256, 64))
    v = jax.random.normal(ks[2], (1, 2, 256, 64))
    t = time_it(lambda: ops.flash_attention(q, k, v, bq=128, bk=128),
                warmup=1, iters=2)
    emit("kernels/flash_attn_interpret/s256", t, "correctness path")


if __name__ == "__main__":
    run()
