"""CI gate: the observability layer must cost ~nothing when disabled.

Measures `Embedder.fit` (the instrumented hot path: plan + kernel +
spans + registry writes) with the obs layer ON and OFF, interleaved
A/B/A/B so drift (thermal, other CI tenants) hits both arms equally,
and compares medians.  The gate fails if the ON median exceeds the OFF
median by more than ``--threshold`` (default 3%, the README's stated
overhead guarantee).

Timing on shared CI runners is noisy, so the gate retries with
escalating iteration counts before failing — a real regression (a
clock read or dict build on the disabled path) is persistent, noise is
not.  Independently of timing, it verifies the disabled path is a
FUNCTIONAL no-op: with obs off, a fit must create zero registry series
and zero trace events.

    PYTHONPATH=src python -m benchmarks.obs_gate [--quick]
"""
from __future__ import annotations

import argparse
import statistics
import sys
import time

import jax
import numpy as np

from repro import obs
from repro.encoder import Embedder, EncoderConfig
from repro.graph.edges import make_labels
from repro.graph.generators import sbm


def _fit_once(g, Y, K):
    emb = Embedder(EncoderConfig(K=K), backend="streaming",
                   plan_cache=None)
    emb.fit(g, Y)
    # both arms must bill the device work: the instrumented path fences
    # inside the span, so an async return here would make the OFF arm
    # look faster by exactly the kernel time
    jax.block_until_ready(emb.Z_)
    return emb.Z_


def _medians(g, Y, K, iters: int) -> tuple[float, float]:
    """(median_on, median_off) over interleaved single-fit timings."""
    on, off = [], []
    for _ in range(iters):
        for arm, out in ((True, on), (False, off)):
            obs.configure(enabled=arm)
            t0 = time.perf_counter()
            _fit_once(g, Y, K)
            out.append(time.perf_counter() - t0)
    obs.configure(enabled=True)
    return statistics.median(on), statistics.median(off)


def _check_noop(g, Y, K) -> list[str]:
    """With obs off, a fit must leave no trace in registry or ring."""
    obs.configure(enabled=False)
    obs.reset()
    _fit_once(g, Y, K)
    problems = []
    if obs.registry().series_names():
        problems.append(
            f"disabled fit created series {obs.registry().series_names()}")
    if obs.trace_events():
        problems.append("disabled fit produced trace events")
    obs.configure(enabled=True)
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4000)
    ap.add_argument("--k", type=int, default=8)
    ap.add_argument("--edges", type=int, default=80_000)
    ap.add_argument("--threshold", type=float, default=0.03,
                    help="max allowed (on - off) / off")
    ap.add_argument("--quick", action="store_true",
                    help="smaller graph / fewer iters (CI smoke)")
    args = ap.parse_args(argv)
    if args.quick:
        args.n, args.edges = 1500, 30_000

    rng = np.random.default_rng(0)
    g, truth = sbm(args.n, args.k, args.edges, p_in=0.85, seed=0)
    Y = make_labels(args.n, args.k, 0.3, rng, true_labels=truth)

    problems = _check_noop(g, Y, args.k)
    for p in problems:
        print(f"[obs-gate] FUNCTIONAL FAIL: {p}", file=sys.stderr)
    if problems:
        return 1
    print("[obs-gate] disabled path is a functional no-op "
          "(0 series, 0 trace events)")

    _fit_once(g, Y, args.k)              # warm compile caches once
    rounds = (5, 9, 15) if args.quick else (7, 13, 21)
    overhead = None
    for iters in rounds:                 # escalate: noise washes out,
        on, off = _medians(g, Y, args.k, iters)   # regressions persist
        overhead = (on - off) / off
        print(f"[obs-gate] iters={iters}: on={on * 1e3:.2f}ms "
              f"off={off * 1e3:.2f}ms overhead={overhead * 100:+.2f}% "
              f"(threshold {args.threshold * 100:.0f}%)")
        if overhead <= args.threshold:
            print("[obs-gate] PASS")
            return 0
    print(f"[obs-gate] FAIL: {overhead * 100:+.2f}% > "
          f"{args.threshold * 100:.0f}% after {rounds[-1]} iters",
          file=sys.stderr)
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
