"""Roofline report: reads the dry-run artifacts (launch/dryrun.py must
have run) and emits the per-cell terms + memory-bound verdict (C6).

The paper's finding "the workload is memory bound; atomics are free"
maps here to: for the GEE cells, memory_s and collective_s dominate
compute_s by orders of magnitude — quantified below.
"""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

ART = os.path.join(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))), "artifacts", "dryrun")


def run() -> None:
    if not os.path.isdir(ART):
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run python -m repro.launch.dryrun --all first")
        return
    for mesh_name in sorted(os.listdir(ART)):
        d = os.path.join(ART, mesh_name)
        for fn in sorted(os.listdir(d)):
            if not fn.endswith(".json"):
                continue
            rec = json.load(open(os.path.join(d, fn)))
            cell = fn[:-5].replace("__", "/")
            step = max(rec.get("compute_s", 0), rec.get("memory_s", 0),
                       rec.get("collective_s", 0))
            probed = "probe" in rec
            # multi-pod cells are compile-proof only (no depth probes):
            # their raw flops are scan-undercounted, so MFU is not
            # meaningful there — flagged instead of printed.
            mfu = (f"mfu={rec.get('mfu', 0):.4f}" if probed
                   else "mfu=n/a(unprobed)")
            emit(f"roofline/{mesh_name}/{cell}", step,
                 f"dom={rec.get('dominant')};"
                 f"compute={rec.get('compute_s', 0):.3e};"
                 f"memory={rec.get('memory_s', 0):.3e};"
                 f"coll={rec.get('collective_s', 0):.3e};{mfu}")
    # C6: GEE memory-bound check
    for mesh_name in sorted(os.listdir(ART)):
        p = os.path.join(ART, mesh_name, "gee__ring.json")
        if os.path.exists(p):
            rec = json.load(open(p))
            ratio = (max(rec["memory_s"], rec["collective_s"])
                     / max(rec["compute_s"], 1e-18))
            emit(f"roofline/{mesh_name}/gee_memory_over_compute", ratio,
                 "C6: paper says memory-bound; ratio >> 1 confirms")


if __name__ == "__main__":
    run()
