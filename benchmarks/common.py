"""Shared benchmark utilities: timing, CSV emission, quick mode.

Every `emit()` row is mirrored into the process metrics registry as a
``repro_bench_<slug>_us`` gauge (`repro.obs`), so bench results share
the export surfaces (snapshot / Prometheus) with the live series and
the harness can validate row names against the repo-wide metric naming
scheme instead of free-form CSV strings.
"""
from __future__ import annotations

import re
import time
from typing import Callable

import jax

from repro import obs

#: set by `benchmarks.run --quick` (the `make bench-smoke` CI path):
#: suites shrink to tiny graphs so every driver is exercised end-to-end
#: in seconds — a rot canary, not a measurement.
QUICK = False

#: set by `benchmarks.run --shards N`: shard count for the serving
#: suite's partitioned-engine rows (`make bench-serving SHARDS=N`).
SHARDS = 2

#: names emitted since the harness last reset it — `benchmarks.run`
#: clears this before each suite and checks it against the driver's
#: `expected_keys()` schema afterwards, so a driver that silently
#: stops emitting rows FAILS instead of passing vacuously.
EMITTED: list = []


def pick(full, quick):
    """Suite-size helper: `full` normally, `quick` under --quick."""
    return quick if QUICK else full


def time_it(fn: Callable, *args, warmup: int = 1, iters: int = 3,
            **kw) -> float:
    """Median wall seconds per call (block_until_ready-aware)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2]


def metric_name(name: str) -> str:
    """Registry series name for a bench row: ``repro_bench_<slug>_us``
    (lowercase, every non-[a-z0-9] run collapsed to one underscore) —
    guaranteed to satisfy `obs.valid_metric_name` for any non-empty
    row name."""
    slug = re.sub(r"[^a-z0-9]+", "_", name.lower()).strip("_")
    return f"repro_bench_{slug or 'row'}_us"


def emit(name: str, seconds: float, derived: str = "") -> None:
    """name,us_per_call,derived CSV row (the harness contract); also
    lands in the metrics registry as a ``repro_bench_*_us`` gauge."""
    EMITTED.append(name)
    obs.gauge(metric_name(name), seconds * 1e6)
    print(f"{name},{seconds * 1e6:.1f},{derived}")
